"""`MetricsRegistry` — the serve stack's one metric surface.

Three instrument kinds, chosen so the serve hot loop never allocates:

- :class:`Counter` — a monotone accumulator (``inc``).  Stays an ``int``
  under integer increments, so telemetry views built over counters keep
  their exact historical payloads (``processed: 512``, never ``512.0``).
- :class:`Gauge` — a last-write-wins value (``set``), or a *callback*
  gauge (``fn=``) evaluated at collection time — the zero-hot-path-cost
  way to expose live state (queue occupancy, realized ratios, jit cache
  sizes) without instrumenting every mutation site.
- :class:`Histogram` — fixed upper-bound buckets with the counts in one
  preallocated ``numpy`` ``int64`` array; ``observe`` is a ``bisect`` +
  two scalar adds, no per-observation dict or list churn.

Instruments are plain objects: they can live **unregistered** (a session
with observability disabled keeps its telemetry counters as private,
detached instruments — same write path, nothing collected) or be created
through a :class:`MetricsRegistry`, which is what the exporters walk.
There is deliberately no global default registry: a registry's lifetime is
a run's lifetime, and two concurrent simulations must not share one.

Snapshot/delta semantics: :meth:`MetricsRegistry.snapshot` materializes
every instrument into a plain dict (deterministically ordered), and
:meth:`MetricsRegistry.delta` diffs two snapshots — how benchmarks report
"what this phase did" without resetting anything.  Exporters:
:meth:`to_prometheus` (text exposition format) and :meth:`to_json`.
"""
from __future__ import annotations

import json
from bisect import bisect_left
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

LabelPairs = Tuple[Tuple[str, str], ...]

#: default latency-ish buckets in simulation time units (RTT, sojourn)
DEFAULT_TIME_BUCKETS: Tuple[float, ...] = (
    0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0,
)


def _labels_key(labels: Optional[Dict[str, str]]) -> LabelPairs:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _format_labels(labels: LabelPairs) -> str:
    if not labels:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + body + "}"


def _format_value(v: Any) -> str:
    """Prometheus sample value: integers stay integral, floats use repr
    (shortest round-trip form, deterministic)."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if f != f:  # NaN
        return "NaN"
    if f in (float("inf"), float("-inf")):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f)


class Counter:
    """A monotone accumulator.  ``value`` stays ``int`` under integer
    increments (telemetry byte-stability depends on it)."""

    __slots__ = ("name", "labels", "help", "value")
    kind = "counter"

    def __init__(self, name: str, labels: LabelPairs = (), help: str = ""):
        self.name = name
        self.labels = labels
        self.help = help
        self.value: Any = 0

    def inc(self, n: Any = 1) -> None:
        self.value += n

    def collect(self) -> Any:
        return self.value


class Gauge:
    """Last-write-wins value, or a collection-time callback (``fn``)."""

    __slots__ = ("name", "labels", "help", "_value", "fn")
    kind = "gauge"

    def __init__(
        self,
        name: str,
        labels: LabelPairs = (),
        help: str = "",
        fn: Optional[Callable[[], Any]] = None,
    ):
        self.name = name
        self.labels = labels
        self.help = help
        self._value: Any = 0
        self.fn = fn

    def set(self, v: Any) -> None:
        self._value = v

    @property
    def value(self) -> Any:
        """The current reading — the callback's, when one is bound."""
        if self.fn is not None:
            return self.fn()
        return self._value

    def collect(self) -> Any:
        return self.value


class Histogram:
    """Fixed-bucket histogram: ``buckets`` are sorted upper bounds, counts
    live in one preallocated ``int64`` array (+1 overflow bin for values
    above the last bound).  ``observe`` allocates nothing."""

    __slots__ = ("name", "labels", "help", "buckets", "counts", "sum", "n")
    kind = "histogram"

    def __init__(
        self,
        name: str,
        buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
        labels: LabelPairs = (),
        help: str = "",
    ):
        edges = tuple(float(b) for b in buckets)
        if not edges or any(nxt <= prev for nxt, prev in zip(edges[1:], edges)):
            raise ValueError(f"buckets must be strictly increasing, got {buckets}")
        self.name = name
        self.labels = labels
        self.help = help
        self.buckets = edges
        self.counts = np.zeros(len(edges) + 1, np.int64)
        self.sum = 0.0
        self.n = 0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.n += 1

    @property
    def mean(self) -> float:
        return self.sum / self.n if self.n else 0.0

    def collect(self) -> Dict[str, Any]:
        return {
            "buckets": list(self.buckets),
            "counts": self.counts.tolist(),
            "sum": self.sum,
            "count": self.n,
        }


class MetricsRegistry:
    """Instrument factory + walkable collection surface.

    ``counter``/``gauge``/``histogram`` are get-or-create keyed on
    ``(name, labels)`` — calling twice returns the same instrument, so
    decoupled components can share a metric without passing objects
    around.  ``collector(fn)`` registers a callable returning extra
    ``(name, labels_dict, value, kind)`` rows evaluated at export time
    (how jit-cache statistics surface without any hot-path hook).
    """

    def __init__(self) -> None:
        self._metrics: Dict[Tuple[str, LabelPairs], Any] = {}
        self._collectors: List[Callable[[], List[Tuple[str, Dict[str, str], Any, str]]]] = []

    # ------------------------------------------------------------- factories

    def _get_or_make(self, cls, name: str, labels, **kw):
        key = (str(name), _labels_key(labels))
        inst = self._metrics.get(key)
        if inst is None:
            inst = cls(key[0], labels=key[1], **kw)
            self._metrics[key] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as {inst.kind}, "
                f"not {cls.kind}"
            )
        return inst

    def counter(
        self, name: str, labels: Optional[Dict[str, str]] = None, help: str = ""
    ) -> Counter:
        return self._get_or_make(Counter, name, labels, help=help)

    def gauge(
        self,
        name: str,
        labels: Optional[Dict[str, str]] = None,
        help: str = "",
        fn: Optional[Callable[[], Any]] = None,
    ) -> Gauge:
        g = self._get_or_make(Gauge, name, labels, help=help)
        if fn is not None:
            # callback gauges rebind freely: a fresh fleet re-registering
            # the same metric name must observe the *new* object's state
            g.fn = fn
        return g

    def histogram(
        self,
        name: str,
        buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
        labels: Optional[Dict[str, str]] = None,
        help: str = "",
    ) -> Histogram:
        return self._get_or_make(Histogram, name, labels, help=help, buckets=buckets)

    def collector(
        self, fn: Callable[[], List[Tuple[str, Dict[str, str], Any, str]]]
    ) -> None:
        self._collectors.append(fn)

    # ------------------------------------------------------------ collection

    def _rows(self) -> List[Tuple[str, LabelPairs, Any, str, str]]:
        """(name, labels, value, kind, help) for every instrument +
        collector row, deterministically ordered."""
        rows = [
            (m.name, m.labels, m.collect(), m.kind, m.help)
            for m in self._metrics.values()
        ]
        for fn in self._collectors:
            for name, labels, value, kind in fn():
                rows.append((str(name), _labels_key(labels), value, kind, ""))
        rows.sort(key=lambda r: (r[0], r[1]))
        return rows

    def snapshot(self) -> Dict[str, Any]:
        """Every metric materialized into plain Python, keyed
        ``name{label="v",...}`` — the delta/export substrate."""
        return {
            f"{name}{_format_labels(labels)}": value
            for name, labels, value, _, _ in self._rows()
        }

    @staticmethod
    def delta(prev: Dict[str, Any], cur: Dict[str, Any]) -> Dict[str, Any]:
        """cur - prev for numeric series (new keys pass through; histogram
        states diff their counts/sum/count)."""
        out: Dict[str, Any] = {}
        for key, value in cur.items():
            base = prev.get(key)
            if base is None:
                out[key] = value
            elif isinstance(value, dict) and isinstance(base, dict):
                out[key] = {
                    "buckets": value["buckets"],
                    "counts": [
                        c - p for c, p in zip(value["counts"], base["counts"])
                    ],
                    "sum": value["sum"] - base["sum"],
                    "count": value["count"] - base["count"],
                }
            elif isinstance(value, (int, float)) and isinstance(base, (int, float)):
                out[key] = value - base
            else:
                out[key] = value
        return out

    # ------------------------------------------------------------- exporters

    def to_prometheus(self) -> str:
        """Prometheus text exposition (0.0.4): HELP/TYPE per family once,
        histogram as cumulative ``_bucket{le=}`` + ``_sum``/``_count``."""
        lines: List[str] = []
        seen_family: set = set()
        for name, labels, value, kind, help_ in self._rows():
            if name not in seen_family:
                seen_family.add(name)
                if help_:
                    lines.append(f"# HELP {name} {help_}")
                lines.append(f"# TYPE {name} {kind}")
            if kind == "histogram":
                cum = 0
                for le, c in zip(value["buckets"], value["counts"]):
                    cum += c
                    le_labels = labels + (("le", _format_value(float(le))),)
                    # keep label order deterministic: le is appended last
                    lines.append(
                        f"{name}_bucket{_format_labels(le_labels)} {cum}"
                    )
                inf_labels = labels + (("le", "+Inf"),)
                lines.append(
                    f"{name}_bucket{_format_labels(inf_labels)} {value['count']}"
                )
                lines.append(
                    f"{name}_sum{_format_labels(labels)} {_format_value(value['sum'])}"
                )
                lines.append(
                    f"{name}_count{_format_labels(labels)} {value['count']}"
                )
            else:
                lines.append(
                    f"{name}{_format_labels(labels)} {_format_value(value)}"
                )
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> Dict[str, Any]:
        """A structured export: one entry per series with kind + value."""
        series = [
            {
                "name": name,
                "labels": {k: v for k, v in labels},
                "kind": kind,
                "value": value,
            }
            for name, labels, value, kind, _ in self._rows()
        ]
        return {"series": series}

    def export_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
