"""`DispatchProfiler` — wall-clock attribution of host-loop time to named
phases.

The serve loop's cost is host-side Python (the ROADMAP's dispatcher fps
regression is "runtime-, not kernel-bound"), so the profiler measures
``perf_counter`` intervals and accumulates them per phase name.  The
instrumentation pattern keeps the disabled path to a single ``is None``
check per phase:

    prof = obs.profiler if obs is not None else None
    ...
    t0 = prof.begin() if prof is not None else 0.0
    do_phase()
    if prof is not None:
        prof.add("phase_name", t0)

``begin``/``add`` are bound-method calls around ``perf_counter`` — no
context-manager frames, no dict churn beyond one setdefault-free lookup
(phase lists are created on first use and reused).
"""
from __future__ import annotations

import time
from typing import Dict, List


class DispatchProfiler:
    """Accumulates ``perf_counter`` seconds per named phase."""

    __slots__ = ("_acc", "_clock")

    def __init__(self) -> None:
        # phase -> [total_seconds, count]
        self._acc: Dict[str, List[float]] = {}
        self._clock = time.perf_counter

    def begin(self) -> float:
        return self._clock()

    def add(self, phase: str, t0: float) -> None:
        cell = self._acc.get(phase)
        if cell is None:
            cell = self._acc[phase] = [0.0, 0]
        cell[0] += self._clock() - t0
        cell[1] += 1

    # ------------------------------------------------------------- reporting

    def totals(self) -> Dict[str, float]:
        return {phase: cell[0] for phase, cell in self._acc.items()}

    def report(self) -> Dict[str, Dict[str, float]]:
        """Per-phase ``{total_ms, count, mean_us, share}`` sorted by cost
        (dict order = descending total)."""
        grand = sum(cell[0] for cell in self._acc.values()) or 1.0
        rows = sorted(self._acc.items(), key=lambda kv: -kv[1][0])
        return {
            phase: {
                "total_ms": cell[0] * 1e3,
                "count": int(cell[1]),
                "mean_us": (cell[0] / cell[1] * 1e6) if cell[1] else 0.0,
                "share": cell[0] / grand,
            }
            for phase, cell in rows
        }

    def format_report(self) -> str:
        lines = [f"{'phase':<28}{'total ms':>10}{'count':>10}{'mean µs':>10}{'share':>8}"]
        for phase, row in self.report().items():
            lines.append(
                f"{phase:<28}{row['total_ms']:>10.2f}{row['count']:>10d}"
                f"{row['mean_us']:>10.2f}{row['share']:>7.1%}"
            )
        return "\n".join(lines)

    def clear(self) -> None:
        self._acc.clear()
