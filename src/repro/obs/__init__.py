"""`repro.obs` — one observability plane for the serve stack.

Everything the runtimes emit flows through a single :class:`Obs` handle
threaded as an optional ``obs=`` argument through ``OffloadSession``,
``OffloadRuntime``, ``EdgeWorker``/``MultiEdgeDispatcher``,
``FleetRuntime``, ``VideoRuntime``, and ``AdaptiveEngine.maybe_update``:

    from repro.obs import Obs
    obs = Obs()
    trace = simulate(engine, features, obs=obs)
    print(obs.metrics.to_prometheus())
    obs.tracer.export("trace.json")     # open in Perfetto
    print(obs.profiler.format_report())

``obs=None`` (the default everywhere) is the noop: instrumented code
guards every emission behind one ``is None`` check, so the disabled cost
is below the noise floor (``bench_obs_overhead`` asserts <3%).

Three sub-planes, each independently disableable:

- :attr:`Obs.metrics` — a :class:`~repro.obs.metrics.MetricsRegistry`
  (counters/gauges/fixed-bucket histograms, Prometheus-text + JSON
  exporters).  Session telemetry counters become registry-backed
  instruments when an obs handle is attached, so ``to_prometheus()``
  exposes live realized ratios, offload decisions, queue depths, and RTT
  histograms with no double accounting.
- :attr:`Obs.tracer` — a :class:`~repro.obs.trace.Tracer` stamping
  nested spans from the simulation's ``ManualClock`` (byte-identical
  traces under a fixed seed) or ``perf_counter`` in benchmarks,
  exported as Chrome-trace JSON.
- :attr:`Obs.profiler` — a :class:`~repro.obs.profiler.DispatchProfiler`
  attributing host-loop wall time to named serve phases.

JIT visibility rides along for free: kernels register their jit entry
points with :mod:`repro.obs.jit_stats` at import time; ``Obs`` snapshots
the process-global cache sizes at construction and exports
``repro_jit_retraces_total{site=...}`` as the growth since then.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.obs import jit_stats
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    DEFAULT_TIME_BUCKETS,
)
from repro.obs.profiler import DispatchProfiler
from repro.obs.trace import SIM_TS_SCALE, WALL_TS_SCALE, Tracer

__all__ = [
    "Obs",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "Tracer",
    "DispatchProfiler",
    "jit_stats",
    "DEFAULT_TIME_BUCKETS",
    "SIM_TS_SCALE",
    "WALL_TS_SCALE",
]


class Obs:
    """The observability handle runtimes accept as ``obs=``.

    ``Obs()`` enables all three planes.  ``Obs(tracing=False)`` etc.
    disable one — the corresponding attribute is ``None`` and
    instrumented code skips its emissions (the same guard as
    ``obs=None``, applied per plane).  :meth:`Obs.noop` disables all
    three while still exercising the seam — what the overhead bench
    measures against.
    """

    __slots__ = ("metrics", "tracer", "profiler", "_jit_baseline")

    def __init__(
        self,
        *,
        metrics: bool = True,
        tracing: bool = True,
        profiling: bool = True,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.metrics: Optional[MetricsRegistry] = MetricsRegistry() if metrics else None
        self.tracer: Optional[Tracer] = Tracer(clock=clock) if tracing else None
        self.profiler: Optional[DispatchProfiler] = (
            DispatchProfiler() if profiling else None
        )
        # retraces are reported relative to handle construction: jit caches
        # are process-global, the handle's lifetime scopes them to a run
        self._jit_baseline = jit_stats.snapshot()
        if self.metrics is not None:
            self.metrics.collector(self._collect_jit)

    @classmethod
    def noop(cls) -> "Obs":
        """All planes disabled — the seam is exercised, nothing is
        recorded (the `bench_obs_overhead` comparison arm)."""
        return cls(metrics=False, tracing=False, profiling=False)

    @property
    def enabled(self) -> bool:
        return (
            self.metrics is not None
            or self.tracer is not None
            or self.profiler is not None
        )

    def bind_clock(
        self, clock: Callable[[], float], ts_scale: float = SIM_TS_SCALE
    ) -> None:
        """Attach the simulation clock (runtimes call this so spans are
        stamped in simulated, not wall, time)."""
        if self.tracer is not None:
            self.tracer.bind_clock(clock, ts_scale)

    # ------------------------------------------------------------ jit plane

    def jit_delta(self) -> Dict[str, Tuple[int, int]]:
        """Per-site ``(retraces, calls)`` since this handle was built."""
        return jit_stats.delta(self._jit_baseline, jit_stats.snapshot())

    def _collect_jit(self) -> List[Tuple[str, Dict[str, str], Any, str]]:
        rows: List[Tuple[str, Dict[str, str], Any, str]] = []
        for site, (retraces, calls) in sorted(self.jit_delta().items()):
            rows.append(
                ("repro_jit_retraces_total", {"site": site}, retraces, "counter")
            )
            if calls:
                rows.append(
                    ("repro_jit_calls_total", {"site": site}, calls, "counter")
                )
        return rows
