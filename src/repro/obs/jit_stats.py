"""Per-callsite JAX retrace/compile accounting with zero hot-path cost.

``jax.jit`` objects expose ``_cache_size()`` — the number of distinct
traces the wrapped function has accumulated (one per unique
shape/dtype/static-arg combination).  A growing cache size *is* the
retrace count, so instead of wrapping every call (which would put a
Python frame on the serve hot path), registration just remembers the jit
object and reads its cache size on demand:

    _score_jit = register_jit("score_pipeline.lax", jax.jit(fn))

``snapshot()`` walks the registry; ``delta(before, after)`` is how a
bench or a serve run reports "this phase retraced N times".  Sites whose
jits are rebuilt per call (``FleetPlane`` builds shard closures inside
each ``score``) can't be registered once — they call :func:`count_call`,
a plain dict increment, to at least expose call frequency.

The registry is module-global on purpose: jit caches are process-global
(module-level jits in the kernels are shared by every engine), so
per-run scoping happens by snapshot-delta, not by registry instance —
:class:`~repro.obs.Obs` captures a baseline at construction and exports
``current - baseline``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

_SITES: Dict[str, Any] = {}
_CALLS: Dict[str, int] = {}


def register_jit(site: str, fn: Any) -> Any:
    """Register a jitted callable under ``site`` and return it unchanged
    (safe to wrap the jit-construction expression in place)."""
    _SITES[str(site)] = fn
    return fn


def count_call(site: str, n: int = 1) -> None:
    """Manual call counter for sites that rebuild their jits per call
    (shard_map closures) — a dict increment, nothing more."""
    _CALLS[site] = _CALLS.get(site, 0) + n


def _cache_size(fn: Any) -> int:
    try:
        return int(fn._cache_size())
    except Exception:
        # not a jax.jit (reference-path plain function) or a jax version
        # without the probe: report 0 rather than breaking observability
        return 0


def snapshot() -> Dict[str, Tuple[int, int]]:
    """``{site: (traces, calls)}`` — ``traces`` is the jit cache size
    (distinct compiled specializations so far), ``calls`` the manual
    counter (0 unless the site uses :func:`count_call`)."""
    out: Dict[str, Tuple[int, int]] = {}
    for site, fn in _SITES.items():
        out[site] = (_cache_size(fn), _CALLS.get(site, 0))
    for site, n in _CALLS.items():
        if site not in _SITES:
            out[site] = (0, n)
    return out


def delta(
    before: Dict[str, Tuple[int, int]], after: Dict[str, Tuple[int, int]]
) -> Dict[str, Tuple[int, int]]:
    """Per-site ``(retraces, calls)`` between two snapshots.  Sites new in
    ``after`` count from zero."""
    out: Dict[str, Tuple[int, int]] = {}
    for site, (traces, calls) in after.items():
        b_traces, b_calls = before.get(site, (0, 0))
        out[site] = (traces - b_traces, calls - b_calls)
    return out


def sites() -> Tuple[str, ...]:
    """Registered site names (tests use this to assert coverage)."""
    return tuple(sorted(set(_SITES) | set(_CALLS)))
