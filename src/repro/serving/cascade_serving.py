"""ORIC-gated cascade serving for LMs (paper §V-A / §VII-B transfer).

The paper's weak/strong detector cascade maps onto LM serving as an
**early-exit cascade**: the "weak detector" is the model truncated at layer
k with the shared LM head (local device); the "strong detector" is the full
depth (edge pod).  The decision system transfers wholesale:

  reward      R_i  = per-request quality delta (NLL_weak − NLL_strong)
  rank xform  cdf fit on a CONTEXT batch of reference requests (Eq. 6) —
              for mAP the context enters the metric itself; for corpus-mean
              quality metrics (NLL) the metric is linear in per-request
              terms, so the context's role reduces to calibrating the
              reward CDF/threshold.  Recorded in DESIGN.md §4.
  estimator   MLP on weak-head logits features (top-k probs, entropy,
              margin — the analogue of top-25 box confidences), trained
              with the Eq. 7 weighted MSE.
  policy      quantile threshold, ratio adjustable at runtime.

Supports dense / vlm / moe / rwkv stacks (any arch whose layers are a
single scan stack, plus MoE's two-stack split).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import EstimatorConfig, RewardEstimator
from repro.core.policy import ThresholdPolicy
from repro.core.reward import CdfTransform
from repro.models.lm import LMConfig, _logits, forward

PyTree = dict


def truncate_params(params: PyTree, cfg: LMConfig, exit_layer: int) -> PyTree:
    """Early-exit params: first ``exit_layer`` layers + shared head."""
    p = {k: v for k, v in params.items() if k not in ("layers", "dense_layers", "moe_layers")}
    if "layers" in params:
        p["layers"] = jax.tree.map(lambda a: a[:exit_layer], params["layers"])
    else:  # moe two-stack
        nD = cfg.first_k_dense
        take_dense = min(exit_layer, nD)
        take_moe = max(exit_layer - nD, 0)
        if take_dense:
            p["dense_layers"] = jax.tree.map(
                lambda a: a[:take_dense], params["dense_layers"]
            )
        p["moe_layers"] = jax.tree.map(lambda a: a[:take_moe], params["moe_layers"])
        if not take_dense:
            p.pop("dense_layers", None)
    return p


def truncated_config(cfg: LMConfig, exit_layer: int) -> LMConfig:
    import dataclasses

    kw = {"num_layers": exit_layer}
    if cfg.arch_type == "moe":
        kw["first_k_dense"] = min(cfg.first_k_dense, exit_layer)
    return dataclasses.replace(cfg, **kw)


def sequence_nll(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-sequence mean NLL.  logits (B,S,V), labels (B,S) with -1 pad."""
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * valid
    return nll.sum(-1) / jnp.maximum(valid.sum(-1), 1)


def logits_features(logits: jnp.ndarray, labels: jnp.ndarray, top_k: int = 8) -> np.ndarray:
    """Per-request features from WEAK-head logits only (deployable inputs):
    mean/max entropy, mean margin, mean top-k probs, mean max-prob."""
    lf = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(lf)
    valid = (labels >= 0)[..., None]
    entropy = -(p * lf).sum(-1)  # (B,S)
    topv, _ = jax.lax.top_k(p, top_k)  # (B,S,k)
    margin = topv[..., 0] - topv[..., 1]
    vmask = labels >= 0
    denom = jnp.maximum(vmask.sum(-1), 1)

    def mavg(x):
        return (x * vmask).sum(-1) / denom

    feats = jnp.concatenate(
        [
            mavg(entropy)[:, None],
            jnp.max(entropy * vmask, axis=-1)[:, None],
            mavg(margin)[:, None],
            mavg(topv[..., 0])[:, None],
            (topv * vmask[..., None]).sum(1) / denom[:, None],  # mean top-k probs
        ],
        axis=-1,
    )
    return np.asarray(feats)


@dataclass
class LMCascade:
    """Trained ORIC-style cascade for an LM."""

    cfg: LMConfig
    exit_layer: int
    estimator: RewardEstimator
    cdf: CdfTransform
    policy: ThresholdPolicy

    @classmethod
    def fit(
        cls,
        params: PyTree,
        cfg: LMConfig,
        exit_layer: int,
        calib_batches,  # iterable of training batches (tokens+labels)
        ratio: float = 0.2,
        epochs: int = 40,
        seed: int = 0,
    ) -> "LMCascade":
        """Compute oracle rewards on calibration data, fit the MORIC-style
        estimator, derive the quantile threshold."""
        wcfg = truncated_config(cfg, exit_layer)
        feats, rewards = [], []
        for batch in calib_batches:
            wparams = truncate_params(params, cfg, exit_layer)
            wlogits, _ = forward(wparams, wcfg, batch)
            slogits, _ = forward(params, cfg, batch)
            nll_w = sequence_nll(wlogits, batch["labels"])
            nll_s = sequence_nll(slogits, batch["labels"])
            rewards.append(np.asarray(nll_w - nll_s))  # >0: offload helps
            feats.append(logits_features(wlogits, batch["labels"]))
        x = np.concatenate(feats)
        r = np.concatenate(rewards)
        cdf = CdfTransform(r)
        y = cdf(r)
        est = RewardEstimator(
            x.shape[1], EstimatorConfig(hidden=(64, 32), epochs=epochs, seed=seed)
        )
        est.fit(x, y)
        policy = ThresholdPolicy(est.predict(x), ratio)
        return cls(cfg=cfg, exit_layer=exit_layer, estimator=est, cdf=cdf, policy=policy)

    def serve_batch(self, params: PyTree, batch: Dict) -> Dict:
        """Weak pass for everyone; strong pass only for offloaded requests.
        Returns per-request NLLs, decisions, and the blended quality."""
        wcfg = truncated_config(self.cfg, self.exit_layer)
        wparams = truncate_params(params, self.cfg, self.exit_layer)
        wlogits, _ = forward(wparams, wcfg, batch)
        x = logits_features(wlogits, batch["labels"])
        est = self.estimator.predict(x)
        offload = self.policy.decide_batch(est)
        nll_w = np.asarray(sequence_nll(wlogits, batch["labels"]))
        # strong pass (in a real deployment only offloaded rows cross the
        # pod axis; here we compute the full batch and select)
        slogits, _ = forward(params, self.cfg, batch)
        nll_s = np.asarray(sequence_nll(slogits, batch["labels"]))
        nll_final = np.where(offload, nll_s, nll_w)
        return {
            "estimates": est,
            "offload": offload,
            "nll_weak": nll_w,
            "nll_strong": nll_s,
            "nll_final": nll_final,
            "offload_ratio": float(np.mean(offload)),
        }
