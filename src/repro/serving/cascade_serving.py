"""ORIC-gated cascade serving for LMs (paper §V-A / §VII-B transfer).

The paper's weak/strong detector cascade maps onto LM serving as an
**early-exit cascade**: the "weak detector" is the model truncated at layer
k with the shared LM head (local device); the "strong detector" is the full
depth (edge pod).  The decision system transfers wholesale and is owned by
one :class:`repro.api.OffloadEngine`:

  reward      R_i  = per-request quality delta (NLL_weak − NLL_strong)
  rank xform  cdf fit on a CONTEXT batch of reference requests (Eq. 6) —
              for mAP the context enters the metric itself; for corpus-mean
              quality metrics (NLL) the metric is linear in per-request
              terms, so the context's role reduces to calibrating the
              reward CDF/threshold.  Recorded in DESIGN.md §4.
  estimator   MLP on weak-head logits features (top-k probs, entropy,
              margin — the analogue of top-25 box confidences), trained
              with the Eq. 7 weighted MSE; single hidden layer so batched
              scoring runs the fused Pallas ``estimator_mlp`` kernel.
  policy      quantile threshold, ratio adjustable at runtime.

Supports dense / vlm / moe / rwkv stacks (any arch whose layers are a
single scan stack, plus MoE's two-stack split).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import LMLogitsFeatures, MLPRewardModel, OffloadEngine
from repro.api.features import logits_features  # re-export (moved to repro.api)
from repro.core.estimator import EstimatorConfig
from repro.models.lm import LMConfig, forward

PyTree = dict

__all__ = [
    "LMCascade",
    "logits_features",
    "sequence_nll",
    "truncate_params",
    "truncated_config",
]


def truncate_params(params: PyTree, cfg: LMConfig, exit_layer: int) -> PyTree:
    """Early-exit params: first ``exit_layer`` layers + shared head."""
    p = {k: v for k, v in params.items() if k not in ("layers", "dense_layers", "moe_layers")}
    if "layers" in params:
        p["layers"] = jax.tree.map(lambda a: a[:exit_layer], params["layers"])
    else:  # moe two-stack
        nD = cfg.first_k_dense
        take_dense = min(exit_layer, nD)
        take_moe = max(exit_layer - nD, 0)
        if take_dense:
            p["dense_layers"] = jax.tree.map(
                lambda a: a[:take_dense], params["dense_layers"]
            )
        p["moe_layers"] = jax.tree.map(lambda a: a[:take_moe], params["moe_layers"])
        if not take_dense:
            p.pop("dense_layers", None)
    return p


def truncated_config(cfg: LMConfig, exit_layer: int) -> LMConfig:
    import dataclasses

    kw = {"num_layers": exit_layer}
    if cfg.arch_type == "moe":
        kw["first_k_dense"] = min(cfg.first_k_dense, exit_layer)
    return dataclasses.replace(cfg, **kw)


def sequence_nll(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-sequence mean NLL.  logits (B,S,V), labels (B,S) with -1 pad."""
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * valid
    return nll.sum(-1) / jnp.maximum(valid.sum(-1), 1)


@dataclass
class LMCascade:
    """Trained ORIC-style cascade for an LM: truncation point + the unified
    decision engine (features → estimator → rank transform → policy)."""

    cfg: LMConfig
    exit_layer: int
    engine: OffloadEngine

    # -- back-compat views of the engine's stack ---------------------------
    @property
    def estimator(self):
        return self.engine.reward_model.estimator

    @property
    def cdf(self):
        return self.engine.transform

    @property
    def policy(self):
        return self.engine.policy

    @classmethod
    def fit(
        cls,
        params: PyTree,
        cfg: LMConfig,
        exit_layer: int,
        calib_batches,  # iterable of training batches (tokens+labels)
        ratio: float = 0.2,
        epochs: int = 40,
        seed: int = 0,
    ) -> "LMCascade":
        """Compute oracle rewards on calibration data, then fit the engine
        (MORIC-style estimator + quantile threshold) in one step."""
        wcfg = truncated_config(cfg, exit_layer)
        extractor = LMLogitsFeatures()
        feats, rewards = [], []
        for batch in calib_batches:
            wparams = truncate_params(params, cfg, exit_layer)
            wlogits, _ = forward(wparams, wcfg, batch)
            slogits, _ = forward(params, cfg, batch)
            nll_w = sequence_nll(wlogits, batch["labels"])
            nll_s = sequence_nll(slogits, batch["labels"])
            rewards.append(np.asarray(nll_w - nll_s))  # >0: offload helps
            feats.append(extractor((wlogits, batch["labels"])))
        engine = OffloadEngine(
            feature_extractor=extractor,
            reward_model=MLPRewardModel(
                config=EstimatorConfig(hidden=(64,), epochs=epochs, seed=seed)
            ),
            ratio=ratio,
        )
        engine.fit(features=np.concatenate(feats), rewards=np.concatenate(rewards))
        return cls(cfg=cfg, exit_layer=exit_layer, engine=engine)

    def serve_batch(self, params: PyTree, batch: Dict) -> Dict:
        """Weak pass for everyone; strong pass only for offloaded requests.
        Returns per-request NLLs, decisions, and the blended quality."""
        wcfg = truncated_config(self.cfg, self.exit_layer)
        wparams = truncate_params(params, self.cfg, self.exit_layer)
        wlogits, _ = forward(wparams, wcfg, batch)
        decision = self.engine.decide((wlogits, batch["labels"]))
        offload = decision.offload
        nll_w = np.asarray(sequence_nll(wlogits, batch["labels"]))
        # strong pass (in a real deployment only offloaded rows cross the
        # pod axis; here we compute the full batch and select)
        slogits, _ = forward(params, self.cfg, batch)
        nll_s = np.asarray(sequence_nll(slogits, batch["labels"]))
        nll_final = np.where(offload, nll_s, nll_w)
        return {
            "estimates": decision.estimates,
            "offload": offload,
            "nll_weak": nll_w,
            "nll_strong": nll_s,
            "nll_final": nll_final,
            "offload_ratio": decision.ratio,
        }

    def serve_stream(
        self,
        params: PyTree,
        batches,
        *,
        micro_batch: int = 8,
        ratio: "float | None" = None,
        session=None,
        set_ratio_at: "Dict[int, float] | None" = None,
    ) -> Dict:
        """Streaming serve: requests arrive batch by batch and flow through
        one :class:`repro.runtime.OffloadSession` in arrival order — the
        stateful counterpart of ``serve_batch`` (policy state, realized-ratio
        telemetry, and mid-stream ``set_ratio_at`` re-budgets carry across
        batches).  Realized rewards (NLL_weak − NLL_strong of each request
        that actually went to the strong model) are recorded into the
        session telemetry, so ``reward_sum / rewards_recorded`` is the mean
        realized quality delta of the offloaded traffic.

        ``set_ratio_at`` maps global request index -> new target ratio.
        Returns concatenated per-request results plus the telemetry."""
        from repro.runtime.session import OffloadSession

        if session is None:
            session = OffloadSession(self.engine, ratio=ratio, micro_batch=micro_batch)
        rebudget = dict(set_ratio_at or {})
        wcfg = truncated_config(self.cfg, self.exit_layer)
        wparams = truncate_params(params, self.cfg, self.exit_layer)
        served = 0
        est, off, nw, ns = [], [], [], []
        for batch in batches:
            # re-budgets land at the nearest batch boundary, in step order
            for step in sorted(rebudget):
                if step < served + int(batch["tokens"].shape[0]):
                    session.set_ratio(rebudget.pop(step))
            wlogits, _ = forward(wparams, wcfg, batch)
            decisions = session.submit_batch((wlogits, batch["labels"]))
            mask = np.array([d.offload for d in decisions], bool)
            nll_w = np.asarray(sequence_nll(wlogits, batch["labels"]))
            slogits, _ = forward(params, self.cfg, batch)
            nll_s = np.asarray(sequence_nll(slogits, batch["labels"]))
            for r in (nll_w - nll_s)[mask]:
                session.record_reward(float(r))
            est.append(np.array([d.estimate for d in decisions]))
            off.append(mask)
            nw.append(nll_w)
            ns.append(nll_s)
            served += len(mask)
        offload = np.concatenate(off) if off else np.zeros(0, bool)
        nll_w = np.concatenate(nw) if nw else np.zeros(0)
        nll_s = np.concatenate(ns) if ns else np.zeros(0)
        return {
            "estimates": np.concatenate(est) if est else np.zeros(0),
            "offload": offload,
            "nll_weak": nll_w,
            "nll_strong": nll_s,
            "nll_final": np.where(offload, nll_s, nll_w),
            "offload_ratio": float(offload.mean()) if offload.size else 0.0,
            "telemetry": session.telemetry.as_dict(),
        }

    def set_ratio(self, ratio: float) -> None:
        """Runtime offload-budget adjustment (delegates to the engine)."""
        self.engine.set_ratio(ratio)

    def save(self, path: str) -> None:
        """Persist the decision stack (not the LM weights) as one artifact."""
        self.engine.save(
            path, extra_meta={"exit_layer": self.exit_layer, "cfg_name": self.cfg.name}
        )

    @classmethod
    def load(cls, path: str, cfg: LMConfig) -> "LMCascade":
        """Rebuild from a saved engine; the LM config/params are supplied by
        the caller (the engine artifact carries only the decision stack)."""
        engine = OffloadEngine.load(path)
        return cls(
            cfg=cfg, exit_layer=int(engine.extra_meta["exit_layer"]), engine=engine
        )
