"""Serving substrate: KV-cache decode loop and ORIC-gated cascade serving
(the paper's offloading pipeline applied to LM early-exit cascades)."""
