"""Batched autoregressive decode loop over ``decode_step``, plus the
engine-gated weak/strong cascade decode (``cascade_generate``)."""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LMConfig, decode_step, forward, prefill


def generate(
    params,
    cfg: LMConfig,
    batch: Dict,
    steps: int,
    capacity: Optional[int] = None,
    greedy: bool = True,
    key=None,
) -> jnp.ndarray:
    """Prefill + ``steps`` greedy/sampled tokens.  Returns (B, steps)."""
    B, S = batch["tokens"].shape
    capacity = capacity or (S + steps)
    logits, cache = prefill(params, cfg, batch, capacity=capacity)

    step_fn = jax.jit(functools.partial(decode_step, cfg=cfg))

    def pick(lg, k):
        if greedy:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg).astype(jnp.int32)

    key = key if key is not None else jax.random.PRNGKey(0)
    toks = []
    tok = pick(logits, key)
    for t in range(steps):
        toks.append(tok)
        if t == steps - 1:
            break
        key, sub = jax.random.split(key)
        logits, cache = step_fn(
            params=params, cache=cache, tokens=tok,
            pos=jnp.asarray(S + t, jnp.int32),
        )
        tok = pick(logits, sub)
    return jnp.stack(toks, axis=1)


def cascade_generate(
    params,
    cfg: LMConfig,
    batch: Dict,
    steps: int,
    *,
    engine=None,
    session=None,
    exit_layer: int,
    micro_batch: int = 8,
    capacity: Optional[int] = None,
    greedy: bool = True,
    key=None,
) -> Dict:
    """Session-gated decode: every request decodes through the early-exit
    (weak) stack; rows the ``OffloadSession`` offloads decode at full depth
    instead.  The decision reads only the weak prompt logits — the same
    deployability constraint as the detection cascade.

    Requests flow through a stream session in arrival (row) order, so
    stateful policies (``token_bucket``) carry across calls when the caller
    passes a long-lived ``session``; passing just ``engine`` opens a
    throwaway session for this batch.  ``batch`` values must share the
    leading batch dimension (dense/rwkv/moe stacks).  Returns generated
    tokens plus the decision trace and session telemetry.
    """
    from repro.runtime.session import OffloadSession
    from repro.serving.cascade_serving import truncate_params, truncated_config

    if session is None:
        if engine is None:
            raise ValueError("pass engine= or session=")
        session = OffloadSession(engine, micro_batch=micro_batch)

    wcfg = truncated_config(cfg, exit_layer)
    wparams = truncate_params(params, cfg, exit_layer)
    wlogits, _ = forward(wparams, wcfg, batch)
    decisions = session.submit_batch((wlogits, batch.get("labels")))
    offload = np.array([d.offload for d in decisions], bool)
    estimates = np.array([d.estimate for d in decisions])

    # decisions are known before decoding (they read only prompt logits), so
    # each row decodes through exactly one stack
    B = int(np.shape(batch["tokens"])[0])
    out = np.zeros((B, steps), dtype=np.int32)
    for p, c, idx in (
        (wparams, wcfg, np.where(~offload)[0]),
        (params, cfg, np.where(offload)[0]),
    ):
        if idx.size:
            sub = {k: jnp.asarray(v)[idx] for k, v in batch.items()}
            toks = generate(
                p, c, sub, steps, capacity=capacity, greedy=greedy, key=key
            )
            out[idx] = np.asarray(toks)
    return {
        "tokens": out,
        "offload": offload,
        "estimates": estimates,
        "offload_ratio": float(offload.mean()) if offload.size else 0.0,
        "telemetry": session.telemetry.as_dict(),
    }
