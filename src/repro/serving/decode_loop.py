"""Batched autoregressive decode loop over ``decode_step``."""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm import LMConfig, decode_step, prefill


def generate(
    params,
    cfg: LMConfig,
    batch: Dict,
    steps: int,
    capacity: Optional[int] = None,
    greedy: bool = True,
    key=None,
) -> jnp.ndarray:
    """Prefill + ``steps`` greedy/sampled tokens.  Returns (B, steps)."""
    B, S = batch["tokens"].shape
    capacity = capacity or (S + steps)
    logits, cache = prefill(params, cfg, batch, capacity=capacity)

    step_fn = jax.jit(functools.partial(decode_step, cfg=cfg))

    def pick(lg, k):
        if greedy:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg).astype(jnp.int32)

    key = key if key is not None else jax.random.PRNGKey(0)
    toks = []
    tok = pick(logits, key)
    for t in range(steps):
        toks.append(tok)
        if t == steps - 1:
            break
        key, sub = jax.random.split(key)
        logits, cache = step_fn(
            params=params, cache=cache, tokens=tok,
            pos=jnp.asarray(S + t, jnp.int32),
        )
        tok = pick(logits, sub)
    return jnp.stack(toks, axis=1)
