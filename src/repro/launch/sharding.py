"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Rules are matched against the flattened param path (e.g.
``layers/attn/wq``) in order; first hit wins.  A spec axis is dropped
(replicated) when the corresponding array dimension isn't divisible by the
mesh axis size — the standard MaxText-style fallback, so e.g. kv-head dims
smaller than the model axis replicate instead of failing to lower.
"""
from __future__ import annotations

import fnmatch
from typing import Any, Dict, Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any
AxisVal = Union[None, str, Tuple[str, ...]]

# (pattern, logical spec per dim). "model"/"batch"/"expert" are logical.
PARAM_RULES: Tuple[Tuple[str, Tuple], ...] = (
    # embed is d_model-sharded: a vocab-sharded table makes the token
    # lookup lower to a full-vocab f32 one-hot matmul whose fwd/bwd
    # all-reduces (B,S,V) f32 per step — found in §Perf hillclimb #1
    ("*embed", (None, "model")),
    ("*unembed", (None, "model")),
    # attention
    ("*attn/wq", (None, "model")),
    ("*attn/wk", (None, "model")),
    ("*attn/wv", (None, "model")),
    ("*attn/wo", ("model", None)),
    ("*attn/bq", ("model",)),
    ("*attn/bk", ("model",)),
    ("*attn/bv", ("model",)),
    # MLA
    ("*attn/w_dkv", (None, "model")),
    ("*attn/w_kr", (None, None)),
    ("*attn/w_uk", (None, "model")),
    ("*attn/w_uv", (None, "model")),
    # MLP
    ("*mlp/gate", (None, "model")),
    ("*mlp/up", (None, "model")),
    ("*mlp/down", ("model", None)),
    ("*mlp/up_b", ("model",)),
    ("*mlp/down_b", (None,)),
    # MoE (leading expert dim -> expert parallel)
    ("*moe/router", (None, None)),
    ("*moe/w_gate", ("expert", None, None)),
    ("*moe/w_up", ("expert", None, None)),
    ("*moe/w_down", ("expert", None, None)),
    ("*moe/shared/gate", (None, "model")),
    ("*moe/shared/up", (None, "model")),
    ("*moe/shared/down", ("model", None)),
    # RWKV6
    ("*tm/wr", (None, "model")),
    ("*tm/wk", (None, "model")),
    ("*tm/wv", (None, "model")),
    ("*tm/wg", (None, "model")),
    ("*tm/wo", ("model", None)),
    ("*tm/cm_k", (None, "model")),
    ("*tm/cm_v", ("model", None)),
    ("*tm/cm_r", (None, "model")),
    # RWKV LoRAs replicate: sharding mix_lora_b's fused (5·M) output dim
    # crosses the stream boundary at the (B,S,5,M) reshape, forcing 2.7 GB
    # f32 all-gathers per layer (fwd + remat'd bwd) — §Perf follow-up
    ("*tm/mix_lora_a", (None, None)),
    ("*tm/mix_lora_b", (None, None)),
    ("*tm/decay_lora_a", (None, None)),
    ("*tm/decay_lora_b", (None, None)),
    # Mamba2
    ("*mamba/in_proj", (None, "model")),
    ("*mamba/out_proj", ("model", None)),
    ("*mamba/conv_w", (None, "model")),
    ("*mamba/conv_b", ("model",)),
    # whisper dec blocks
    ("*self_attn/wq", (None, "model")),
    ("*self_attn/wk", (None, "model")),
    ("*self_attn/wv", (None, "model")),
    ("*self_attn/wo", ("model", None)),
    ("*cross_attn/wq", (None, "model")),
    ("*cross_attn/wk", (None, "model")),
    ("*cross_attn/wv", (None, "model")),
    ("*cross_attn/wo", ("model", None)),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _resolve(spec_logical: Tuple, mapping: Dict[str, AxisVal], shape, mesh: Mesh):
    """Logical spec -> PartitionSpec, dropping non-divisible axes.

    Leading stacked-layer dims (len(shape) > len(spec)) are left unsharded:
    the rule spec aligns to the TRAILING dims of the array.
    """
    pad = len(shape) - len(spec_logical)
    out = [None] * pad
    for dim, logical in zip(range(pad, len(shape)), spec_logical):
        if logical is None:
            out.append(None)
            continue
        axes = mapping.get(logical)
        if axes is None:
            out.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        size = int(np.prod([mesh.shape[a] for a in ax_tuple]))
        if shape[dim] % size == 0:
            out.append(axes)
        else:
            out.append(None)  # replicate: dim not divisible
    return P(*out)


def param_shardings(
    params_abstract: PyTree,
    mesh: Mesh,
    mapping: Dict[str, AxisVal],
    mode: str = "tp",
) -> PyTree:
    """NamedSharding pytree matching ``params_abstract``.

    mode="tp" (baseline): tensor-parallel over `model`, replicated over the
    data axes (grads all-reduce across data).
    mode="fsdp": additionally shards each large parameter's first free dim
    over `data` (ZeRO-3-style; params all-gather at use, grads
    reduce-scatter) — a §Perf lever for collective-bound training.
    """
    data_axis = mapping.get("data_only", "data")
    data_size = mesh.shape.get(data_axis, 1) if not isinstance(data_axis, tuple) else 1
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_abstract)
    out = []
    for path, leaf in flat:
        key = _path_str(path)
        spec = P()
        for pattern, logical in PARAM_RULES:
            if fnmatch.fnmatch(key, pattern):
                spec = _resolve(logical, mapping, leaf.shape, mesh)
                break
        if mode == "fsdp" and int(np.prod(leaf.shape)) >= 1_000_000:
            parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
            for d in range(len(leaf.shape)):
                if parts[d] is None and leaf.shape[d] % data_size == 0:
                    parts[d] = data_axis
                    break
            spec = P(*parts)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(
    batch_abstract: Dict, mesh: Mesh, mapping: Dict[str, AxisVal]
) -> Dict:
    """Batch inputs: leading batch dim over the (pod×)data axes, except
    positions_3d whose batch dim is axis 1."""
    out = {}
    for k, v in batch_abstract.items():
        if k == "positions_3d":
            logical = (None, "batch") + (None,) * (len(v.shape) - 2)
        else:
            logical = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, _resolve(logical, mapping, v.shape, mesh))
    return out


# Cache sharding rules keyed by cache-dict field name.  Baseline ("seq"):
# KV caches shard the slot (sequence) dim over `model` — sequence-parallel
# decode — and batch over data; recurrent states shard heads over `model`.
# "heads" mode shards kv-heads over `model` instead (replicates when the
# head count is not divisible); "batch" shards only the batch dim.
CACHE_RULES: Dict[str, Tuple] = {
    "k": (None, "batch", "model", None, None),
    "v": (None, "batch", "model", None, None),
    "k_s": (None, "batch", "model", None),
    "v_s": (None, "batch", "model", None),
    "c": (None, "batch", "model", None),
    "kr": (None, "batch", "model", None),
    "xk": (None, "batch", None, None, None),
    "xv": (None, "batch", None, None, None),
    "state": (None, "batch", "model", None, None),
    "tm_x": (None, "batch", None),
    "cm_x": (None, "batch", None),
    "ssm": (None, None, "batch", "model", None, None),
    "conv": (None, None, "batch", None, "model"),
    "shared_k": (None, "batch", "model", None, None),
    "shared_v": (None, "batch", "model", None, None),
}

CACHE_RULES_HEADS: Dict[str, Tuple] = {
    **CACHE_RULES,
    "k": (None, "batch", None, "model", None),
    "v": (None, "batch", None, "model", None),
    "shared_k": (None, "batch", None, "model", None),
    "shared_v": (None, "batch", None, "model", None),
    "c": (None, "batch", None, "model"),  # latent dim over model
    "kr": (None, "batch", None, None),
}

CACHE_RULES_BATCH: Dict[str, Tuple] = {
    k: tuple(a if a == "batch" else None for a in v) for k, v in CACHE_RULES.items()
}

CACHE_RULES_HEADDIM: Dict[str, Tuple] = {
    **CACHE_RULES,
    # head_dim over model: the decode DUS update is then local on every
    # shard (slot-sharded caches make the one-slot write cross-shard),
    # at the cost of an all-reduce of the per-step attention logits
    "k": (None, "batch", None, None, "model"),
    "v": (None, "batch", None, None, "model"),
    "shared_k": (None, "batch", None, None, "model"),
    "shared_v": (None, "batch", None, None, "model"),
    "c": (None, "batch", None, "model"),
    "kr": (None, "batch", None, None),
}

CACHE_MODES = {
    "seq": CACHE_RULES,
    "heads": CACHE_RULES_HEADS,
    "batch": CACHE_RULES_BATCH,
    "headdim": CACHE_RULES_HEADDIM,
}


def cache_shardings(
    cache_abstract: Dict, mesh: Mesh, mapping: Dict[str, AxisVal], mode: str = "seq"
) -> Dict:
    rules = CACHE_MODES[mode]
    out = {}
    for k, v in cache_abstract.items():
        logical = rules[k]
        out[k] = NamedSharding(mesh, _resolve(logical, mapping, v.shape, mesh))
    return out


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
