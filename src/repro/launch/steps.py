"""Step builders: train_step / prefill_step / serve_step per architecture.

These are the functions the dry-run lowers and the drivers jit.  All take
``cfg`` statically (closures) so ``jax.jit`` sees pure array signatures.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm import LMConfig, decode_step, loss_fn, prefill
from repro.train.adamw import AdamWState, adamw_init, adamw_update

PyTree = Any


def make_train_step(cfg: LMConfig, lr: float = 1e-4):
    """(params, opt_state, batch) -> (params, opt_state, loss)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
        params, opt_state = adamw_update(grads, opt_state, params, lr)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: LMConfig, capacity: int):
    """(params, batch) -> (last-token logits, decode cache)."""

    def prefill_step(params, batch):
        return prefill(params, cfg, batch, capacity=capacity)

    return prefill_step


def make_serve_step(cfg: LMConfig):
    """(params, cache, tokens, pos) -> (logits, cache) — ONE new token
    against a seq_len-deep cache (the decode shapes' hot path)."""

    def serve_step(params, cache, tokens, pos):
        if cfg.arch_type == "vlm":
            p3d = jnp.broadcast_to(pos, (3, tokens.shape[0], 1)).astype(jnp.int32)
            return decode_step(params, cfg, cache, tokens, pos, p3d)
        return decode_step(params, cfg, cache, tokens, pos)

    return serve_step


def abstract_opt_state(params_abstract: PyTree) -> AdamWState:
    """ShapeDtypeStruct AdamW state matching abstract params."""
    zeros = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params_abstract
    )
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=zeros,
        nu=jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params_abstract),
    )
