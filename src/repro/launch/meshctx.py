"""Mesh context threading for sharding constraints inside model code.

Model code calls ``constrain(x, "batch", None, "model")`` with *logical*
axis names; the launcher binds logical -> mesh axes here.  With no mesh
bound (single-device smoke tests) constraints are no-ops, so the same model
code runs on 1 CPU device and on the 512-chip production mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, AxisVal]]]:
    return getattr(_state, "bound", None)


@contextlib.contextmanager
def bind_mesh(mesh: Mesh, logical_axes: Dict[str, AxisVal]):
    """Bind a mesh + logical-axis mapping, e.g.
    ``{"batch": ("pod", "data"), "model": "model"}``."""
    prev = _current()
    _state.bound = (mesh, logical_axes)
    try:
        yield
    finally:
        _state.bound = prev


def constrain(x, *logical_axes: Optional[str]):
    """with_sharding_constraint using logical axis names; no-op if unbound."""
    bound = _current()
    if bound is None:
        return x
    mesh, mapping = bound
    spec = P(*[mapping.get(a) if a is not None else None for a in logical_axes])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*logical_axes: Optional[str]) -> Optional[NamedSharding]:
    """NamedSharding for jit in_shardings/out_shardings; None if unbound."""
    bound = _current()
    if bound is None:
        return None
    mesh, mapping = bound
    spec = P(*[mapping.get(a) if a is not None else None for a in logical_axes])
    return NamedSharding(mesh, spec)
