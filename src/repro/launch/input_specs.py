"""ShapeDtypeStruct stand-ins for every (architecture × input shape).

The four assigned shapes:

  train_4k       seq= 4,096  global_batch=256   train_step
  prefill_32k    seq=32,768  global_batch= 32   prefill_step
  decode_32k     seq=32,768  global_batch=128   serve_step (1 token vs cache)
  long_500k      seq=524,288 global_batch=  1   serve_step, sub-quadratic

``long_500k`` swaps in the sliding-window (8192) attention variant for
attention archs (repro.configs.long_context_variant); SSM/RWKV state decode
needs no window.  No device memory is allocated here — everything is
ShapeDtypeStruct.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config, long_context_variant
from repro.models.lm import LMConfig, init_cache

S = jax.ShapeDtypeStruct

SHAPES: Dict[str, Dict] = {
    "train_4k": {"seq_len": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "batch": 1, "kind": "decode"},
}

WINDOW = 8192  # sliding window for long_500k attention variants


def resolve_config(arch: str, shape: str) -> LMConfig:
    cfg = get_config(arch)
    if shape == "long_500k":
        cfg = long_context_variant(cfg, WINDOW)
    return cfg


def batch_specs(cfg: LMConfig, B: int, seq: int) -> Dict[str, Any]:
    """Training/prefill batch ShapeDtypeStructs."""
    batch: Dict[str, Any] = {"tokens": S((B, seq), jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = S((B, cfg.vision_tokens, cfg.d_model), cfg.act_dtype)
        batch["positions_3d"] = S((3, B, seq), jnp.int32)
    if cfg.arch_type == "encdec":
        batch["audio_frames"] = S((B, cfg.encoder_frames, cfg.d_model), cfg.act_dtype)
    return batch


def input_specs(arch: str, shape: str) -> Tuple[LMConfig, Dict[str, Any]]:
    """Returns (cfg, specs) where specs' structure depends on the shape kind:

      train   -> {"batch": {tokens, labels, ...}}
      prefill -> {"batch": {tokens, ...}}
      decode  -> {"cache": <cache pytree>, "tokens": (B,), "pos": ()}
    """
    cfg = resolve_config(arch, shape)
    meta = SHAPES[shape]
    B, seq = meta["batch"], meta["seq_len"]
    kind = meta["kind"]
    if kind == "train":
        batch = batch_specs(cfg, B, seq)
        batch["labels"] = S((B, seq), jnp.int32)
        return cfg, {"kind": kind, "batch": batch}
    if kind == "prefill":
        return cfg, {"kind": kind, "batch": batch_specs(cfg, B, seq)}
    # decode: ONE token against a seq-deep cache
    capacity = min(seq, cfg.window) if cfg.window > 0 else seq
    cache = init_cache(cfg, B, capacity, abstract=True)
    return cfg, {
        "kind": kind,
        "cache": cache,
        "tokens": S((B,), jnp.int32),
        "pos": S((), jnp.int32),
        "capacity": capacity,
    }
