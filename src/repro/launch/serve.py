"""Serving launcher: batched prefill + decode on a reduced config, with
optional ORIC cascade gating (the paper's offloading pipeline).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_7b --tokens 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_7b --cascade
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.lm_synth import synth_lm_batch
from repro.models.lm import init_params, reduced
from repro.serving.decode_loop import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--cascade", action="store_true")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks, labels = synth_lm_batch(rng, args.batch, args.prompt_len, cfg.vocab_size)
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jnp.zeros((args.batch, cfg.vision_tokens, cfg.d_model), jnp.float32)
        batch["positions_3d"] = jnp.broadcast_to(
            jnp.arange(args.prompt_len)[None, None], (3, args.batch, args.prompt_len)
        )
    if cfg.arch_type == "encdec":
        batch["audio_frames"] = jnp.asarray(
            rng.normal(0, 1, (args.batch, cfg.encoder_frames, cfg.d_model)), jnp.float32
        )

    if args.cascade and cfg.arch_type in ("dense", "vlm", "moe", "rwkv"):
        from repro.serving.cascade_serving import LMCascade

        cal = dict(batch, labels=jnp.asarray(labels))
        cascade = LMCascade.fit(params, cfg, exit_layer=max(cfg.num_layers // 2, 1),
                                calib_batches=[cal], ratio=0.25, epochs=10)
        out = cascade.serve_batch(params, cal)
        print(f"cascade: offload_ratio={out['offload_ratio']:.2f} "
              f"nll weak={out['nll_weak'].mean():.4f} "
              f"strong={out['nll_strong'].mean():.4f} "
              f"final={out['nll_final'].mean():.4f}")
        return

    t0 = time.time()
    toks_out = generate(params, cfg, batch, steps=args.tokens)
    dt = time.time() - t0
    print(f"[{cfg.name}] generated {toks_out.shape} in {dt:.2f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s)")
    print("first row:", np.asarray(toks_out[0])[:12])


if __name__ == "__main__":
    main()
