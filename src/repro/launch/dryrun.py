"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) —
the first two lines below force 512 host platform devices BEFORE any jax
import so ``jax.make_mesh`` can build the production meshes.  Do not import
this module from tests (they need the real 1-device view).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS  # noqa: E402
from repro.launch.input_specs import SHAPES, input_specs  # noqa: E402
from repro.launch.mesh import (  # noqa: E402
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    logical_axes,
    make_production_mesh,
)
from repro.launch.meshctx import bind_mesh  # noqa: E402
from repro.launch.sharding import (  # noqa: E402
    batch_shardings,
    cache_shardings,
    param_shardings,
    replicated,
)
from repro.launch.steps import (  # noqa: E402
    abstract_opt_state,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.models.lm import abstract_params, init_cache  # noqa: E402

ARTIFACTS = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_COLL_LINE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-type bytes (per-device result shapes) from HLO text."""
    out = {c: 0.0 for c in COLLECTIVES}
    count = {c: 0 for c in COLLECTIVES}
    for m in _COLL_LINE.finditer(hlo_text):
        result_ty, op = m.group(1), m.group(2)
        b = 0.0
        for dt, dims in _SHAPE.findall(result_ty):
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            b += n * _DTYPE_BYTES.get(dt, 4)
        out[op] += b
        count[op] += 1
    out_all = dict(out)
    out_all["total"] = sum(out.values())
    out_all["counts"] = count
    return out_all


def _lower(arch: str, shape: str, multi_pod: bool, overrides: Optional[Dict] = None):
    overrides = overrides or {}
    cfg, specs = input_specs(arch, shape)
    cfg_over = {k: v for k, v in overrides.items()
                if k not in ("param_mode", "cache_mode")}
    if cfg_over:
        import dataclasses

        cfg = dataclasses.replace(cfg, **cfg_over)
        _, specs = input_specs(arch, shape)  # re-derive shapes if needed
        if specs["kind"] == "decode":
            cap = min(SHAPES[shape]["seq_len"], cfg.window) if cfg.window > 0 else SHAPES[shape]["seq_len"]
            specs["cache"] = init_cache(cfg, SHAPES[shape]["batch"], cap, abstract=True)
    param_mode = overrides.get("param_mode", "tp")
    cache_mode = overrides.get("cache_mode", "seq")
    mesh = make_production_mesh(multi_pod=multi_pod)
    mapping = logical_axes(multi_pod=multi_pod)
    params_abs = abstract_params(cfg)
    with bind_mesh(mesh, mapping):
        p_sh = param_shardings(params_abs, mesh, mapping, mode=param_mode)
        if specs["kind"] == "train":
            opt_abs = abstract_opt_state(params_abs)
            opt_sh = param_shardings(opt_abs, mesh, mapping, mode=param_mode)
            b_sh = batch_shardings(specs["batch"], mesh, mapping)
            step = make_train_step(cfg)
            with mesh:
                lowered = jax.jit(
                    step,
                    in_shardings=(p_sh, opt_sh, b_sh),
                    out_shardings=(p_sh, opt_sh, replicated(mesh)),
                    donate_argnums=(0, 1),
                ).lower(params_abs, opt_abs, specs["batch"])
        elif specs["kind"] == "prefill":
            b_sh = batch_shardings(specs["batch"], mesh, mapping)
            seq = SHAPES[shape]["seq_len"]
            step = make_prefill_step(cfg, capacity=seq)
            B = SHAPES[shape]["batch"]
            cache_abs = init_cache(cfg, B, seq, abstract=True)
            c_sh = cache_shardings(cache_abs, mesh, mapping, mode=cache_mode)
            vocab_ax = mapping["model"] if cfg.vocab_size % 16 == 0 else None
            logits_sh = NamedSharding(mesh, P(mapping["batch"], vocab_ax))
            with mesh:
                lowered = jax.jit(
                    step,
                    in_shardings=(p_sh, b_sh),
                    out_shardings=(logits_sh, c_sh),
                ).lower(params_abs, specs["batch"])
        else:  # decode
            c_sh = cache_shardings(specs["cache"], mesh, mapping, mode=cache_mode)
            B = specs["tokens"].shape[0]
            tok_sh = NamedSharding(
                mesh, P(mapping["batch"] if B % 16 == 0 else None)
            )
            vocab_ax = mapping["model"] if cfg.vocab_size % 16 == 0 else None
            logits_sh = NamedSharding(
                mesh,
                P(mapping["batch"] if B % 16 == 0 else None, vocab_ax),
            )
            step = make_serve_step(cfg)
            with mesh:
                lowered = jax.jit(
                    step,
                    in_shardings=(p_sh, c_sh, tok_sh, replicated(mesh)),
                    out_shardings=(logits_sh, c_sh),
                    donate_argnums=(1,),
                ).lower(params_abs, specs["cache"], specs["tokens"], specs["pos"])
    return cfg, lowered, mesh


def model_flops(cfg, shape_name: str) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), D = tokens."""
    meta = SHAPES[shape_name]
    D = meta["batch"] * (meta["seq_len"] if meta["kind"] != "decode" else 1)
    # active params per token
    M, L = cfg.d_model, cfg.num_layers
    emb = 2 * cfg.vocab_size * M  # embed+unembed
    if cfg.arch_type == "moe":
        if cfg.use_mla:
            attn = M * cfg.num_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim) + \
                M * (cfg.kv_lora_rank + cfg.qk_rope_dim) + \
                cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_dim + cfg.head_dim) + \
                cfg.num_heads * cfg.head_dim * M
        else:
            attn = 2 * M * cfg.num_heads * cfg.head_dim + 2 * M * cfg.num_kv_heads * cfg.head_dim
        ff_act = 3 * M * cfg.d_ff_expert * (cfg.top_k + cfg.num_shared_experts)
        dense_ff = 3 * M * cfg.d_ff
        n_active = (L - cfg.first_k_dense) * (attn + ff_act) + cfg.first_k_dense * (attn + dense_ff) + emb
    elif cfg.arch_type == "rwkv":
        per = 5 * M * M + M * M + 2 * M * cfg.d_ff  # time-mix + channel-mix
        n_active = L * per + emb
    elif cfg.arch_type == "hybrid":
        mc = cfg.mamba()
        per_m = M * (2 * mc.d_inner + 2 * mc.d_state + mc.num_heads) + mc.d_inner * M
        shared = 4 * M * cfg.num_heads * cfg.head_dim + 3 * M * cfg.d_ff
        n_active = cfg.num_mamba_layers * per_m + cfg.num_shared_attn * shared + emb
    elif cfg.arch_type == "encdec":
        per_dec = 8 * M * cfg.num_heads * cfg.head_dim + 2 * M * cfg.d_ff
        per_enc = 4 * M * cfg.num_heads * cfg.head_dim + 2 * M * cfg.d_ff
        n_active = L * per_dec + cfg.encoder_layers * per_enc + emb
    else:  # dense / vlm
        attn = 2 * M * cfg.num_heads * cfg.head_dim + 2 * M * cfg.num_kv_heads * cfg.head_dim
        n_active = L * (attn + 3 * M * cfg.d_ff) + emb
    mult = 6 if meta["kind"] == "train" else 2
    return float(mult) * n_active * D


def _probe_depths(cfg) -> tuple:
    """Two reduced depths preserving per-layer structure for linear
    extrapolation of cost in depth (see cost_probe)."""
    if cfg.arch_type == "hybrid":
        p = cfg.shared_attn_period
        return p, 2 * p  # 1 group, 2 groups
    if cfg.arch_type == "moe" and cfg.first_k_dense:
        return cfg.first_k_dense + 1, cfg.first_k_dense + 2
    return 2, 4


def _probe_cfg(cfg, L: int):
    import dataclasses

    kw = dict(num_layers=L, layer_unroll=-1, attn_chunk=0)
    if cfg.arch_type == "encdec":
        kw["encoder_layers"] = L  # enc+dec scale together; full depths equal
    return dataclasses.replace(cfg, **kw)


def cost_probe(arch: str, shape: str, multi_pod: bool = False,
               overrides: Optional[Dict] = None) -> Dict[str, float]:
    """Depth-corrected HLO cost: XLA's cost_analysis counts a while-loop
    body ONCE regardless of trip count, so the plain dry-run undercounts
    everything inside the layer scan by ~num_layers.  We lower the same
    config at two reduced depths with the layer scan FULLY UNROLLED and
    attention unchunked (lax.map has the same once-counting problem), then
    extrapolate linearly in depth:

        cost(L) = outside + L · per_layer
        per_layer = (c_b - c_a) / (L_b - L_a)

    Exact for every term linear in depth (flops, bytes, grad all-reduces,
    MoE all-to-alls).  Residual undercount: the time-recurrence inner scans
    of RWKV/Mamba (elementwise outer products; added analytically in
    `recurrence_flops`).
    """
    cfg0, _ = input_specs(arch, shape)
    La, Lb = _probe_depths(cfg0)
    Lfull = cfg0.num_layers
    costs = []
    for L in (La, Lb):
        import repro.launch.input_specs as ispec

        orig = ispec.resolve_config
        try:
            ispec.resolve_config = lambda a, s: _probe_cfg(orig(a, s), L)  # noqa: B023
            _, lowered, mesh = _lower(arch, shape, multi_pod, overrides)
        finally:
            ispec.resolve_config = orig
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        coll = collective_bytes(compiled.as_text())
        costs.append(
            {
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": coll["total"],
            }
        )
    out = {}
    for k in ("flops", "bytes", "coll"):
        per_layer = (costs[1][k] - costs[0][k]) / (Lb - La)
        out[k] = max(costs[0][k] + (Lfull - La) * per_layer, 0.0)
    out["flops"] += recurrence_flops(cfg0, shape, multi_pod)
    return out


def recurrence_flops(cfg, shape: str, multi_pod: bool) -> float:
    """Analytic per-device flops of time-recurrence scan bodies (counted
    once by cost_analysis even in the probes)."""
    meta = SHAPES[shape]
    n_batch_shards = (32 if multi_pod else 16) if meta["batch"] % 16 == 0 else 1
    B = meta["batch"] / n_batch_shards
    S = meta["seq_len"] if meta["kind"] != "decode" else 1
    if cfg.arch_type == "rwkv":
        # per step/head: 3 outer-product-scale ops on (K,V) + readout
        return 8.0 * B * S * cfg.num_layers * cfg.d_model * cfg.rwkv_head_size
    if cfg.arch_type == "hybrid":
        mc = cfg.mamba()
        return 8.0 * B * S * cfg.num_mamba_layers * mc.d_inner * mc.d_state
    return 0.0


def dryrun_one(arch: str, shape: str, multi_pod: bool, save: bool = True,
               overrides: Optional[Dict] = None, tag_suffix: str = "") -> Dict:
    tag = f"{arch}_{shape}_{'multipod' if multi_pod else 'singlepod'}{tag_suffix}"
    t0 = time.time()
    cfg, lowered, mesh = _lower(arch, shape, multi_pod, overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    n_dev = mesh.devices.size
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    # depth-corrected costs (see cost_probe docstring); single-pod only to
    # bound sweep time — multi-pod reuses the structure proof, not the table
    corrected = None
    if not multi_pod:
        try:
            corrected = cost_probe(arch, shape, multi_pod, overrides)
        except Exception as e:  # noqa: BLE001
            print(f"[dryrun] cost_probe failed for {tag}: {e}")
    if corrected is not None:
        flops_dev, bytes_dev = corrected["flops"], corrected["bytes"]
        coll_total = corrected["coll"]
    else:
        coll_total = coll["total"]
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_bytes": coll_total,
            "raw_uncorrected": {
                "hlo_flops": float(cost.get("flops", 0.0)),
                "hlo_bytes": float(cost.get("bytes accessed", 0.0)),
                "collective_bytes": coll["total"],
            },
            "depth_corrected": corrected is not None,
            "collectives": {k: v for k, v in coll.items() if k != "counts"},
            "collective_counts": coll["counts"],
        },
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "roofline": {
            "compute_s": flops_dev / PEAK_FLOPS_BF16,
            "memory_s": bytes_dev / HBM_BW,
            "collective_s": coll_total / ICI_BW,
        },
        "model_flops_total": model_flops(cfg, shape),
    }
    r = result["roofline"]
    result["roofline"]["dominant"] = max(r, key=lambda k: r[k])
    result["model_flops_ratio"] = (
        result["model_flops_total"] / (flops_dev * n_dev) if flops_dev else None
    )
    if save:
        os.makedirs(ARTIFACTS, exist_ok=True)
        with open(os.path.join(ARTIFACTS, tag + ".json"), "w") as f:
            json.dump(result, f, indent=2)
    print(
        f"[dryrun] {tag}: compile {t_compile:.1f}s  "
        f"flops/dev {flops_dev:.3e}  bytes/dev {bytes_dev:.3e}  "
        f"coll/dev {coll['total']:.3e}  dominant={result['roofline']['dominant']}"
    )
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--override", default=None,
                    help='JSON dict of perf overrides, e.g. '
                         '\'{"param_mode": "fsdp", "capacity_factor": 1.0}\'')
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    args = ap.parse_args()
    overrides = json.loads(args.override) if args.override else None
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multipod' if mp else 'singlepod'}{args.tag}"
                path = os.path.join(ARTIFACTS, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[dryrun] skip {tag} (exists)")
                    continue
                try:
                    dryrun_one(arch, shape, mp, overrides=overrides,
                               tag_suffix=args.tag)
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"[dryrun] FAIL {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("[dryrun] all combinations lowered and compiled OK")


if __name__ == "__main__":
    main()
