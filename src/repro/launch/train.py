"""Training launcher.

Local mode (default): trains a scaled-down variant of ``--arch`` on
synthetic tokens with the SAME train_step the dry-run lowers for the
production mesh.  ``--dryrun`` delegates to repro.launch.dryrun for the
mesh lowering (512 host devices).

  PYTHONPATH=src python -m repro.launch.train --arch yi_6b --steps 30
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b --shape train_4k
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.lm_synth import synth_lm_batch
from repro.launch.steps import make_train_step
from repro.models.lm import init_params, reduced
from repro.train.adamw import adamw_init
from repro.train.checkpoint import save_pytree


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=args.lr), donate_argnums=(0, 1))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for it in range(args.steps):
        toks, labels = synth_lm_batch(rng, args.batch, args.seq, cfg.vocab_size)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        if cfg.arch_type == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model), jnp.float32
            )
            batch["positions_3d"] = jnp.broadcast_to(
                jnp.arange(args.seq)[None, None], (3, args.batch, args.seq)
            )
        if cfg.arch_type == "encdec":
            batch["audio_frames"] = jnp.asarray(
                rng.normal(0, 1, (args.batch, cfg.encoder_frames, cfg.d_model)),
                jnp.float32,
            )
        params, opt, loss = step(params, opt, batch)
        if it % 10 == 0 or it == args.steps - 1:
            print(f"[{cfg.name}] step {it} loss {float(loss):.4f} "
                  f"({(it+1)/(time.time()-t0):.2f} it/s)")
    if args.ckpt:
        save_pytree(args.ckpt, params)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
