"""Production mesh construction (TPU v5e-class target).

A function (NOT a module-level constant) so importing never touches jax
device state.  Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod: 2 pods = 512 chips, axes ("pod", "data", "model"); the batch
shards over (pod, data) and params replicate across pods (DP) while the
`model` axis carries tensor/expert parallelism within a pod — matching the
paper's local-device/edge-tier split, where the `pod` axis separates tiers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh

AxisVal = Union[None, str, Tuple[str, ...]]

#: the serving mesh axis streams shard over (see ``repro.fleet``)
FLEET_AXIS = "shard"


def make_production_mesh(*, multi_pod: bool = False):
    """The full-scale training/serving mesh — 256 chips (single pod) or
    2x256 (multi-pod).

    Degrades gracefully when fewer devices are visible (single-host CPU
    CI): the available devices fold into the ``data`` axis with the other
    axes at size 1, so the axis *names* — and therefore every logical
    sharding rule — stay valid; size-1 axes simply replicate."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n_avail = len(jax.devices())
    if n_avail < int(np.prod(shape)):
        shape = (1, n_avail, 1) if multi_pod else (n_avail, 1)
    return jax.make_mesh(shape, axes)


def make_fleet_mesh(n_shards: Optional[int] = None, *, axis: str = FLEET_AXIS) -> Mesh:
    """A 1-D city-scale *serving* mesh: ``n_shards`` devices along one
    ``"shard"`` axis, streams sharded over it (see ``repro.fleet.plane``).

    Built for CPU host-device fan-out: under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` every host
    thread pool slice becomes a shard.  ``n_shards=None`` takes every
    visible device; asking for more shards than devices clamps to the
    available count (a 1-device CI run gets a 1-shard mesh and the sharded
    data plane degrades to the single-device path)."""
    devices = jax.devices()
    n = len(devices) if n_shards is None else int(n_shards)
    if n < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    n = min(n, len(devices))
    return Mesh(np.array(devices[:n]), (axis,))


def logical_axes(*, multi_pod: bool = False) -> Dict[str, AxisVal]:
    """Logical -> mesh axis mapping used by meshctx.constrain."""
    return {
        "batch": ("pod", "data") if multi_pod else "data",
        "model": "model",
        "expert": "model",  # expert-parallel over the model axis
        "data_only": "data",
    }


# Hardware constants (per chip) for the roofline terms — TPU v5e class.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link
