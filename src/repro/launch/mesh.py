"""Production mesh construction (TPU v5e-class target).

A function (NOT a module-level constant) so importing never touches jax
device state.  Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod: 2 pods = 512 chips, axes ("pod", "data", "model"); the batch
shards over (pod, data) and params replicate across pods (DP) while the
`model` axis carries tensor/expert parallelism within a pod — matching the
paper's local-device/edge-tier split, where the `pod` axis separates tiers.
"""
from __future__ import annotations

from typing import Dict, Tuple, Union

import jax

AxisVal = Union[None, str, Tuple[str, ...]]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def logical_axes(*, multi_pod: bool = False) -> Dict[str, AxisVal]:
    """Logical -> mesh axis mapping used by meshctx.constrain."""
    return {
        "batch": ("pod", "data") if multi_pod else "data",
        "model": "model",
        "expert": "model",  # expert-parallel over the model axis
        "data_only": "data",
    }


# Hardware constants (per chip) for the roofline terms — TPU v5e class.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link
